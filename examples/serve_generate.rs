//! Generation example: load a trained checkpoint and sample continuations
//! through the `logits` artifact (greedy / temperature sampling driven from
//! Rust — the artifact returns last-position logits).
//!
//! ```bash
//! cargo run --release --example serve_generate -- [ckpt] [-o policy=fp4]
//!     [-o gen=96]
//! ```
//!
//! `-o policy=<arm>` picks the lowered manifest arm (`fp4`, `bf16`,
//! `w4a8_dge_k5`, ...) instead of the old hardcoded "fp4" string, and the
//! arm is resolved through [`fp4train::policy::arms::for_manifest_arm`]
//! so the canonical [`PrecisionPolicy`] it corresponds to is printed —
//! the serve path speaks the same policy grammar as everything else.
//! For the full serving engine (continuous batching, quantized KV cache,
//! rate limiting) see `fp4train serve` and [`fp4train::serve`].
//!
//! Without a checkpoint argument it trains the arm briefly first so the
//! sample shows learned statistics rather than uniform noise.
//!
//! [`PrecisionPolicy`]: fp4train::policy::PrecisionPolicy

use std::sync::Arc;

use fp4train::cli::Args;
use fp4train::coordinator::{checkpoint, Trainer};
use fp4train::data::corpus::{Corpus, CorpusKind};
use fp4train::data::loader::{BatchLoader, LoaderConfig};
use fp4train::policy::arms::for_manifest_arm;
use fp4train::runtime::Engine;
use fp4train::util::Rng;

fn main() -> anyhow::Result<()> {
    // Args::parse treats the first item as the command name, so feed it
    // a synthetic one ahead of the real example arguments.
    let args = Args::parse(
        std::iter::once("serve_generate".to_string()).chain(std::env::args().skip(1)),
    )?;
    let ckpt = args.positional.first().cloned();
    let arm = args.get("policy").unwrap_or("fp4").to_string();
    let gen_len = args.get_usize("gen", 96)?;

    match for_manifest_arm(&arm) {
        Some(p) => println!("manifest arm {arm:?} resolves to precision policy: {p}"),
        None => println!("manifest arm {arm:?} has no policy-level description"),
    }

    let engine = Arc::new(Engine::load("artifacts")?);
    let mut trainer = Trainer::new(engine.clone(), "nano", &arm, 0)?;
    let corpus = Corpus::generate(CorpusKind::Code, 1234, 2_000_000, 64 * 1024);

    match ckpt {
        Some(path) => {
            let ck = checkpoint::load(&path)?;
            let spec = trainer.entry.step("init")?.clone();
            trainer.replace_state(checkpoint::to_literals(&ck, &spec.outputs)?)?;
            println!("restored {path} (step {})", ck.step);
        }
        None => {
            println!("no checkpoint given; training nano/{arm} for 128 steps on `code`...");
            let model = trainer.entry.model.clone();
            let loader = BatchLoader::new(
                &corpus,
                LoaderConfig {
                    batch: model.batch,
                    seq_len: model.seq_len,
                    ..Default::default()
                },
            );
            let recs = trainer.run(&loader, 128)?;
            println!("  trained to loss {:.4}", recs.last().unwrap().loss);
        }
    }

    // --- batched generation through the logits artifact ---
    let spec = trainer.entry.step("logits")?.clone();
    let tok_io = spec.inputs.last().unwrap().clone();
    let (b, s) = (tok_io.shape[0], tok_io.shape[1]);
    let model = trainer.entry.model.clone();

    // B prompts from the held-out split
    let mut rows: Vec<Vec<i32>> = (0..b)
        .map(|i| {
            let start = i * 200;
            corpus.heldout[start..start + s].iter().map(|&x| x as i32).collect()
        })
        .collect();
    println!("\ngenerating {gen_len} bytes for {b} prompts (greedy-ish, temp 0.8):");

    let mut rng = Rng::new(42);
    let t0 = std::time::Instant::now();
    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
    for _ in 0..gen_len {
        let mut toks = Vec::with_capacity(b * s);
        for row in &rows {
            toks.extend_from_slice(&row[row.len() - s..]);
        }
        let tokens = Engine::tokens_literal(&tok_io, &toks)?;
        let mut lit_args: Vec<&xla::Literal> = trainer.params().iter().collect();
        lit_args.push(&tokens);
        let outs = engine.run(&spec, &lit_args)?;
        let logits = Engine::to_f32_vec(&outs[0])?; // (B, V)
        for (i, row) in rows.iter_mut().enumerate() {
            let v = model.vocab;
            let slice = &logits[i * v..(i + 1) * v];
            let next = sample(slice, 0.8, &mut rng);
            row.push(next);
            generated[i].push(next);
        }
    }
    let bytes = b * gen_len;
    println!(
        "generated {bytes} bytes in {:.2}s ({:.1} B/s, batched {b}-wide)\n",
        t0.elapsed().as_secs_f64(),
        bytes as f64 / t0.elapsed().as_secs_f64()
    );
    for (i, g) in generated.iter().enumerate().take(4) {
        let text: String = g
            .iter()
            .map(|&t| {
                let c = (t.rem_euclid(256)) as u8;
                if c.is_ascii_graphic() || c == b' ' || c == b'\n' {
                    c as char
                } else {
                    '�'
                }
            })
            .collect();
        println!("--- sample {i} ---\n{text}\n");
    }
    Ok(())
}

fn sample(logits: &[f32], temp: f32, rng: &mut Rng) -> i32 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| ((l - max) / temp).exp()).collect();
    let total: f32 = exps.iter().sum();
    let mut u = rng.unit_f32() * total;
    for (i, &e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (exps.len() - 1) as i32
}
