//! Quantized all-reduce on a two-tier fabric — no artifacts needed.
//!
//! 64 simulated workers sit in 8 nodes of 8. The hierarchical all-reduce
//! keeps the plentiful intra-node links at FP8 and squeezes the scarce
//! inter-node links down to FP4 rows — one policy string:
//!
//! ```text
//! wire=fp8:e4m3,wire.inter=fp4:e2m1/row
//! ```
//!
//! The demo reduces a synthetic gradient through three wire policies on
//! the same topology, prints the per-link-class byte ledger, and reports
//! each arm's error against the exact f32 mean.
//!
//! ```bash
//! cargo run --release --example fabric_allreduce
//! ```

use fp4train::fabric::{flat_reference_mean, Fabric, LinkClass, SyntheticSource, Topology};
use fp4train::policy::PrecisionPolicy;

fn rmse(a: &[f32], b: &[f32]) -> f64 {
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum();
    (s / a.len() as f64).sqrt()
}

fn main() -> anyhow::Result<()> {
    let topology = Topology::parse("hier:8x8")?;
    let n = 1 << 16; // one 64k-element gradient tensor, shaped 256x256
    let (rows, cols) = (256, 256);
    let src = SyntheticSource { workers: topology.workers(), len: n, seed: 42 };

    let mut exact = Vec::new();
    flat_reference_mean(&src, &mut exact);

    println!("two-tier all-reduce on {topology}: {n} f32 grads per worker\n");
    let arms = [
        ("f32 everywhere", "wire=f32"),
        ("fp8 everywhere", "wire=fp8:e4m3"),
        ("fp8 intra / fp4 inter", "wire=fp8:e4m3,wire.inter=fp4:e2m1/row"),
    ];
    let mut baseline = 0u64;
    for (name, policy_str) in arms {
        let policy = PrecisionPolicy::parse(policy_str)?;
        let (_, specs) = policy.link_resolution_at(0);

        let mut fabric = Fabric::new(topology)?;
        let mut out = Vec::new();
        fabric.all_reduce_mean(&src, rows, cols, &specs, &mut out)?;

        println!("{name}  ({policy_str})");
        for link in LinkClass::ALL {
            let l = fabric.stats.link(link);
            if l.sends == 0 {
                continue;
            }
            println!(
                "  {:>5} links: {:>3} sends, {:>8.1} KB  ({:.2}x vs f32)",
                link,
                l.sends,
                l.bytes as f64 / 1024.0,
                l.bytes_f32_equiv as f64 / l.bytes as f64,
            );
        }
        let total = fabric.stats.total_bytes();
        if baseline == 0 {
            baseline = total;
        }
        println!(
            "  total {:>8.1} KB ({:.1}% of the f32 wire), rmse vs exact mean {:.2e}\n",
            total as f64 / 1024.0,
            100.0 * total as f64 / baseline as f64,
            rmse(&out, &exact),
        );
    }
    println!(
        "the mixed policy pays FP8 only where links are cheap — the scarce \
         inter-node tier ships FP4 rows (paper §4.1, FP8-LM comm pushed one \
         format further)"
    );
    Ok(())
}
