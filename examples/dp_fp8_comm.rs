//! Data-parallel simulation with quantized gradient communication (§4.1 /
//! FP8-LM): 4 workers on disjoint corpus shards, gradients byte-encoded
//! on the wire per the `Wire` class of a `PrecisionPolicy`, averaged,
//! applied via the `apply` artifact. Compares loss trajectory and wire
//! bytes across FP8, FP4-row and f32 communication.
//!
//! ```bash
//! make artifacts && cargo run --release --example dp_fp8_comm
//! ```

use std::sync::Arc;

use fp4train::coordinator::dp::DpSim;
use fp4train::data::corpus::{Corpus, CorpusKind};
use fp4train::policy::PrecisionPolicy;
use fp4train::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(48);
    let workers = 4;
    let engine = Arc::new(Engine::load("artifacts")?);
    let corpus = Corpus::generate(CorpusKind::Mix, 1234, 2_000_000, 64 * 1024);

    let mut results = Vec::new();
    for wire in ["fp8:e4m3", "fp4:e2m1/row", "f32"] {
        let policy = PrecisionPolicy::parse(&format!("wire={wire}"))?;
        let comm = policy.wire_spec_at(0);
        let mut sim =
            DpSim::new(engine.clone(), "nano", "bf16", &corpus, workers, 0, policy)?;
        println!("\n=== {} ===", sim.context_label());
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let loss = sim.dp_step()?;
            if step % 8 == 0 || step + 1 == steps {
                println!("step {step:>3}  mean worker loss {loss:.4}");
            }
        }
        println!(
            "{} steps in {:.1}s — wire {:.2} MB (f32-equiv {:.2} MB, {:.2}x compression)",
            steps,
            t0.elapsed().as_secs_f64(),
            sim.stats.bytes_sent as f64 / 1e6,
            sim.stats.bytes_f32_equiv as f64 / 1e6,
            sim.compression()
        );
        results.push((comm, *sim.losses.last().unwrap(), sim.stats.bytes_sent));
    }

    let (_, l_base, b_base) = results[results.len() - 1]; // f32 baseline
    println!();
    for (comm, loss, bytes) in &results {
        println!(
            "final loss {comm}: {loss:.4} (gap vs f32 {:+.4}); wire {bytes} \
             bytes ({:.2}x saved)",
            loss - l_base,
            b_base as f64 / *bytes as f64
        );
    }
    println!(
        "— the paper's FP8 gradient communication preserves training while \
         ~4x-ing bandwidth; fp4:e2m1/row halves the wire again"
    );
    Ok(())
}
