//! Data-parallel simulation with FP8 gradient communication (§4.1 /
//! FP8-LM): 4 workers on disjoint corpus shards, gradients byte-encoded
//! to E4M3 on the wire, averaged, applied via the `apply` artifact.
//! Compares the loss trajectory and wire bytes against f32 communication.
//!
//! ```bash
//! make artifacts && cargo run --release --example dp_fp8_comm
//! ```

use std::sync::Arc;

use fp4train::coordinator::dp::{CommPrecision, DpSim};
use fp4train::data::corpus::{Corpus, CorpusKind};
use fp4train::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(48);
    let workers = 4;
    let engine = Arc::new(Engine::load("artifacts")?);
    let corpus = Corpus::generate(CorpusKind::Mix, 1234, 2_000_000, 64 * 1024);

    let mut results = Vec::new();
    for comm in [CommPrecision::Fp8, CommPrecision::F32] {
        let mut sim =
            DpSim::new(engine.clone(), "nano", "bf16", &corpus, workers, 0, comm)?;
        println!("\n=== {} ===", sim.context_label());
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let loss = sim.dp_step()?;
            if step % 8 == 0 || step + 1 == steps {
                println!("step {step:>3}  mean worker loss {loss:.4}");
            }
        }
        println!(
            "{} steps in {:.1}s — wire {:.2} MB (f32-equiv {:.2} MB, {:.2}x compression)",
            steps,
            t0.elapsed().as_secs_f64(),
            sim.stats.bytes_sent as f64 / 1e6,
            sim.stats.bytes_f32_equiv as f64 / 1e6,
            sim.compression()
        );
        results.push((comm, *sim.losses.last().unwrap(), sim.stats.bytes_sent));
    }

    let (c0, l0, b0) = results[0];
    let (c1, l1, b1) = results[1];
    println!(
        "\nfinal loss {c0:?}: {l0:.4} vs {c1:?}: {l1:.4} (gap {:+.4}); \
         bytes {b0} vs {b1} ({:.2}x saved) — the paper's FP8 gradient \
         communication preserves training while ~4x-ing bandwidth",
        l0 - l1,
        b1 as f64 / b0 as f64
    );
    Ok(())
}
