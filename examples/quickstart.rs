//! Quickstart: train the `nano` model under the paper's FP4 recipe and the
//! BF16 baseline on the same data, side by side, and print the loss gap.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use fp4train::coordinator::Trainer;
use fp4train::data::corpus::{Corpus, CorpusKind};
use fp4train::data::loader::{BatchLoader, LoaderConfig, Sampler};
use fp4train::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Arc::new(Engine::load("artifacts")?);
    println!("PJRT platform: {}", engine.platform());

    let corpus = Corpus::generate(CorpusKind::Mix, 1234, 2_000_000, 64 * 1024);
    let steps = 96;

    let mut finals = Vec::new();
    for policy in ["bf16", "fp4"] {
        let mut trainer = Trainer::new(engine.clone(), "nano", policy, 0)?;
        let model = trainer.entry.model.clone();
        println!(
            "\n=== nano/{policy}: {} params, seq {}, batch {} ===",
            model.param_count, model.seq_len, model.batch
        );
        let loader = BatchLoader::new(
            &corpus,
            LoaderConfig {
                batch: model.batch,
                seq_len: model.seq_len,
                seed: 0,
                ..Default::default()
            },
        );
        let t0 = std::time::Instant::now();
        let recs = trainer.run(&loader, steps)?;
        for r in recs.iter().step_by(16) {
            println!("  step {:>3}  loss {:.4}  gnorm {:.3}", r.step, r.loss, r.gnorm);
        }
        let windows = Sampler::heldout_windows(&corpus, model.seq_len);
        let heldout = trainer.eval_loss(&windows)?;
        let last = recs.last().unwrap();
        println!(
            "  {} steps in {:.1}s — train {:.4}, held-out {:.4}",
            recs.len(),
            t0.elapsed().as_secs_f64(),
            last.loss,
            heldout
        );
        finals.push((policy, heldout));
    }

    println!(
        "\nFP4 vs BF16 held-out gap after {steps} steps: {:+.4} nats \
         (paper: FP4 tracks BF16 with a small gap)",
        finals[1].1 - finals[0].1
    );
    Ok(())
}
