//! END-TO-END driver (DESIGN.md deliverable): train the ~100M-parameter
//! `m100` preset under the full FP4 recipe (W4A4 + DGE + OCC, vector-wise,
//! mixed-precision Adam) for a few hundred steps on the synthetic corpus,
//! logging the loss curve and a held-out eval. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts-e2e && cargo run --release --example train_100m -- [steps]
//! ```

use std::sync::Arc;

use fp4train::coordinator::Trainer;
use fp4train::data::corpus::{Corpus, CorpusKind};
use fp4train::data::loader::{BatchLoader, LoaderConfig, Sampler};
use fp4train::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(304); // 19 bursts of 16
    let engine = Arc::new(Engine::load("artifacts")?);
    let mut trainer = Trainer::new(engine.clone(), "m100", "fp4", 0)?;
    let model = trainer.entry.model.clone();
    println!(
        "m100/fp4: {} parameters ({} layers, dim {}, ffn {}), seq {}, batch {}",
        model.param_count, model.n_layers, model.dim, model.ffn_dim,
        model.seq_len, model.batch
    );

    let corpus = Corpus::generate(CorpusKind::Mix, 1234, 8_000_000, 128 * 1024);
    let loader = BatchLoader::new(
        &corpus,
        LoaderConfig {
            batch: model.batch,
            seq_len: model.seq_len,
            seed: 0,
            prefetch: 8,
            ..Default::default()
        },
    );
    let windows = Sampler::heldout_windows(&corpus, model.seq_len);

    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < steps {
        let chunk = 48.min(steps - done);
        let recs = trainer.run(&loader, chunk)?;
        done = trainer.step;
        let last = recs.last().unwrap();
        let tok_s = (done * model.batch * model.seq_len) as f64 / t0.elapsed().as_secs_f64();
        println!(
            "step {:>4}/{steps}  loss {:.4}  gnorm {:.3}  ({:.0} tok/s)",
            last.step, last.loss, last.gnorm, tok_s
        );
    }
    println!(
        "\ntrained {} steps ({} tokens) in {:.1}s — final train loss {:.4} \
         (init ≈ ln 256 = 5.545)",
        trainer.step,
        trainer.step * model.batch * model.seq_len,
        t0.elapsed().as_secs_f64(),
        trainer.history.last().unwrap().loss,
    );
    trainer.write_history_csv("results/e2e/m100_fp4_loss.csv")?;
    let spec = trainer.entry.step("init")?.clone();
    fp4train::coordinator::checkpoint::save(
        "results/e2e/m100_fp4.ckpt",
        trainer.step as u64,
        &spec.outputs,
        trainer.state(),
    )?;
    println!("loss curve -> results/e2e/m100_fp4_loss.csv");
    println!("checkpoint -> results/e2e/m100_fp4.ckpt");
    // Held-out eval is best-effort: compiling the second (eval) executable
    // for a 100M-param graph can exceed memory on small boxes.
    match trainer.eval_loss(&windows) {
        Ok(h) => println!("held-out loss {h:.4}"),
        Err(e) => println!("held-out eval skipped ({e:#})"),
    }
    Ok(())
}
